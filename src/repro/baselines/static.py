"""Status-quo baselines (§4.1.5): satellite-only and GS-only.

Both are thin adapters over the shared ``CascadeExecutor`` with static
policies (``SatelliteOnlyPolicy`` / ``GroundOnlyPolicy``) — the same
executor that runs SpaceVerse and the request server, so baseline numbers
and cascade numbers always come from identical forward-pass code.  GS-only
optionally applies the naive random-masking redundancy reduction used in the
Fig. 3 / Fig. 12 studies.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import eo_adapter as EO
from repro.core.cascade import TierModel, CascadeConfig
from repro.core.latency import LatencyModel, DEFAULT_LINK
from repro.core.similarity import task_simi
from repro.network.link import LinkModel
from repro.serving.engine_core import shared_core
from repro.serving.executor import CascadeExecutor
from repro.serving.offload import OffloadPipeline
from repro.serving.policy import GroundOnlyPolicy, SatelliteOnlyPolicy


def _eval_loop(run_batch, task, data, batch_size=32):
    n = data["images"].shape[0]
    outs = []
    for i in range(0, n, batch_size):
        sl = slice(i, min(i + batch_size, n))
        outs.append(run_batch(jnp.asarray(data["images"][sl]),
                              jnp.asarray(data["prompts"][sl])))
    pred = np.concatenate([np.asarray(o["pred"]) for o in outs])
    lat = np.concatenate([o["latency_s"] for o in outs])
    label = (data["region_rel"] if task == "det" else data["labels"])[:n]
    simi = np.asarray(task_simi(task, jnp.asarray(pred), jnp.asarray(label)))
    out = {"performance": float(simi.mean()), "latency_s": float(lat.mean()),
           "per_sample_latency": lat, "per_sample_simi": simi}
    if "offload" in outs[0]:
        out["offload_rate"] = float(np.concatenate(
            [o["offload"] for o in outs]).mean())
    return out


def _executor(tier_a: TierModel, tier_b: TierModel,
              adapter_cfg: EO.EOAdapterConfig, cc: CascadeConfig,
              latency: LatencyModel, link: LinkModel) -> CascadeExecutor:
    pipeline = OffloadPipeline(adapter_cfg, cc, latency, link=link)
    return CascadeExecutor(shared_core(tier_a, adapter_cfg),
                           shared_core(tier_b, adapter_cfg),
                           adapter_cfg, pipeline)


class SatelliteOnly:
    """Everything runs on the compact onboard model."""

    def __init__(self, sat: TierModel, adapter_cfg: EO.EOAdapterConfig,
                 cc: Optional[CascadeConfig] = None,
                 latency: Optional[LatencyModel] = None):
        self.sat, self.ac = sat, adapter_cfg
        self.cc = cc or CascadeConfig()
        self.lat = latency or LatencyModel()
        self.policy = SatelliteOnlyPolicy()

    def run_batch(self, images, prompts, task: str):
        ex = _executor(self.sat, self.sat, self.ac, self.cc, self.lat,
                       DEFAULT_LINK)
        res = ex.run_counterfactual(self.policy, task, images, prompts,
                                    self.cc.answer_vocab)
        l_ans = self.ac.answer_len(task)
        lat = (self.lat.sat_encode_s() + self.lat.sat_prefill_s()
               + self.lat.sat_decode_s(l_ans))
        return {"pred": res.pred,
                "latency_s": np.full((images.shape[0],), lat)}

    def evaluate(self, task, data, batch_size=32):
        return _eval_loop(lambda im, pr: self.run_batch(im, pr, task),
                          task, data, batch_size)


class GSOnly:
    """Everything offloads; raw images transit the link (optionally with the
    naive random-masking reduction at ``keep_frac``)."""

    def __init__(self, gs: TierModel, adapter_cfg: EO.EOAdapterConfig,
                 cc: Optional[CascadeConfig] = None,
                 latency: Optional[LatencyModel] = None,
                 link: LinkModel = DEFAULT_LINK,
                 keep_frac: Optional[float] = None, seed: int = 0):
        self.gs, self.ac = gs, adapter_cfg
        self.cc = cc or CascadeConfig()
        self.lat, self.link = latency or LatencyModel(), link
        self.keep_frac = keep_frac
        self.policy = GroundOnlyPolicy(keep_frac=keep_frac, seed=seed)

    def run_batch(self, images, prompts, task: str):
        b = images.shape[0]
        ex = _executor(self.gs, self.gs, self.ac, self.cc, self.lat,
                       self.link)
        res = ex.run_counterfactual(self.policy, task, images, prompts,
                                    self.cc.answer_vocab)
        frac = np.asarray(res.gs_view.bytes_frac)
        full_bytes = self.lat.full_bytes(task)
        l_ans = self.ac.answer_len(task)
        tx = np.array([self.lat.tx_s(self.link, full_bytes * f)
                       for f in frac])
        gs_s = np.asarray(self.lat.gs_infer_s(l_ans, res.gs_view.kept_frac))
        return {"pred": res.pred, "latency_s": tx + gs_s,
                "offload": np.ones((b,), bool)}

    def evaluate(self, task, data, batch_size=32):
        return _eval_loop(lambda im, pr: self.run_batch(im, pr, task),
                          task, data, batch_size)
