"""Tabi (EuroSys'23) — multi-level inference with a single confidence score.

Faithful to the comparison in §4.1.5 / §4.2: Tabi completes the FULL onboard
inference for every sample, derives one confidence value from the output
token probabilities (mean max-prob), and re-runs low-confidence samples on
the large model.  Its attention-based pruning applies to text tokens, so
offloaded Earth-observation images transit the link at full size — both
properties the paper identifies as Tabi's latency overhead (≈69.9 % extra
onboard time, no transmission reduction).
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp
import numpy as np

from repro.core import eo_adapter as EO
from repro.core.cascade import TierModel, CascadeConfig
from repro.core.latency import LatencyModel, DEFAULT_LINK
from repro.baselines.static import _eval_loop
from repro.network.link import LinkModel


class Tabi:
    def __init__(self, sat: TierModel, gs: TierModel,
                 adapter_cfg, cc: CascadeConfig = CascadeConfig(),
                 latency: LatencyModel = LatencyModel(),
                 link: LinkModel = DEFAULT_LINK,
                 threshold: float = 0.7, word_prune_frac: float = 0.3):
        self.sat, self.gs, self.ac, self.cc = sat, gs, adapter_cfg, cc
        self.lat, self.link = latency, link
        self.threshold = threshold
        # attention-based word pruning shortens the GS text prompt only
        self.word_prune_frac = word_prune_frac

    def confidence(self, probs: jnp.ndarray) -> jnp.ndarray:
        """Mean max answer-token probability (B, L, V) → (B,)."""
        return probs.max(-1).mean(-1)

    def run_batch(self, images, prompts, task: str):
        b = images.shape[0]
        l_ans = self.ac.answer_len(task)
        sat_toks, sat_probs = EO.generate(self.sat.params, self.sat.cfg,
                                          self.ac, task, images, prompts,
                                          self.cc.answer_vocab)
        conf = self.confidence(sat_probs)
        offload = np.asarray(conf < self.threshold)
        gs_toks, _ = EO.generate(self.gs.params, self.gs.cfg, self.ac, task,
                                 images, prompts, self.cc.answer_vocab)
        sat_pred = EO.prediction_from_tokens(task, sat_toks)
        gs_pred = EO.prediction_from_tokens(task, gs_toks)
        off_j = jnp.asarray(offload)
        pred = jnp.where(off_j[:, None] if task == "det" else off_j,
                         gs_pred, sat_pred)
        # latency: full onboard always; offloaded add full-image tx + GS
        onboard = (self.lat.sat_encode_s() + self.lat.sat_prefill_s()
                   + self.lat.sat_decode_s(l_ans))
        tx = self.lat.tx_s(self.link, self.lat.full_bytes(task))
        text_frac = 1.0 - self.word_prune_frac
        gs_s = 2 * self.lat.gs_params * (
            self.lat.deploy_patches + self.lat.deploy_text * text_frac
            + l_ans) / self.lat.gs_flops
        lat = onboard + offload * (tx + gs_s)
        return {"pred": pred, "latency_s": lat, "offload": offload}

    def evaluate(self, task, data, batch_size=32):
        return _eval_loop(lambda im, pr: self.run_batch(im, pr, task),
                          task, data, batch_size)
