"""Tabi (EuroSys'23) — multi-level inference with a single confidence score.

Faithful to the comparison in §4.1.5 / §4.2: Tabi completes the FULL onboard
inference for every sample, derives one confidence value from the output
token probabilities (mean max-prob), and re-runs low-confidence samples on
the large model.  Its attention-based pruning applies to text tokens, so
offloaded Earth-observation images transit the link at full size — both
properties the paper identifies as Tabi's latency overhead (≈69.9 % extra
onboard time, no transmission reduction).

Expressed as a ``TabiPolicy`` over the shared ``CascadeExecutor``: a single
full-answer decode chunk, one post-decode confidence decision, full-image
GS view.  Only the latency accounting (text pruning on the GS prompt) stays
here.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.cascade import TierModel, CascadeConfig
from repro.core.latency import LatencyModel, DEFAULT_LINK
from repro.baselines.static import _eval_loop, _executor
from repro.network.link import LinkModel
from repro.serving.policy import TabiPolicy


class Tabi:
    def __init__(self, sat: TierModel, gs: TierModel,
                 adapter_cfg, cc: Optional[CascadeConfig] = None,
                 latency: Optional[LatencyModel] = None,
                 link: LinkModel = DEFAULT_LINK,
                 threshold: float = 0.7, word_prune_frac: float = 0.3):
        self.sat, self.gs, self.ac = sat, gs, adapter_cfg
        self.cc = cc or CascadeConfig()
        self.lat, self.link = latency or LatencyModel(), link
        self.threshold = threshold
        # attention-based word pruning shortens the GS text prompt only
        self.word_prune_frac = word_prune_frac
        self.policy = TabiPolicy(threshold)

    def confidence(self, probs: jnp.ndarray) -> jnp.ndarray:
        """Mean max answer-token probability (B, L, V) → (B,)."""
        return self.policy.confidence(probs)

    def run_batch(self, images, prompts, task: str):
        l_ans = self.ac.answer_len(task)
        ex = _executor(self.sat, self.gs, self.ac, self.cc, self.lat,
                       self.link)
        res = ex.run_counterfactual(self.policy, task, images, prompts,
                                    self.cc.answer_vocab)
        offload = np.asarray(res.offload)
        # latency: full onboard always; offloaded add full-image tx + GS
        onboard = (self.lat.sat_encode_s() + self.lat.sat_prefill_s()
                   + self.lat.sat_decode_s(l_ans))
        tx = self.lat.tx_s(self.link, self.lat.full_bytes(task))
        text_frac = 1.0 - self.word_prune_frac
        gs_s = 2 * self.lat.gs_params * (
            self.lat.deploy_patches + self.lat.deploy_text * text_frac
            + l_ans) / self.lat.gs_flops
        lat = onboard + offload * (tx + gs_s)
        return {"pred": res.pred, "latency_s": lat, "offload": offload}

    def evaluate(self, task, data, batch_size=32):
        return _eval_loop(lambda im, pr: self.run_batch(im, pr, task),
                          task, data, batch_size)
