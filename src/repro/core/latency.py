"""Analytic latency model, calibrated to the paper's testbed (§4.1, Fig. 4).

Accuracy in our reproduction comes from really-executed proxy LVLMs; latency
comes from this model evaluated at the paper's DEPLOYED pair (Qwen2-VL-2B on
a Jetson AGX Xavier, Qwen2-VL-7B on 8×RTX 3090) and its measured link
(110.67 Mb/s).  Calibration targets from the paper:
 - GS-only ≈ 4.14× satellite-only latency on DOTA,
 - transmission ≈ 76.4 % of GS-only time,
 - contact windows ≈ 4.33 % of the orbital period (throughput studies).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.network.link import LinkModel
from repro.network.orbit import ContactPlan


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    sat_params: float = 2.0e9           # W^s  (Qwen2-VL-2B)
    gs_params: float = 7.6e9            # W^g  (Qwen2-VL-7B)
    sat_flops: float = 20.0e12          # Jetson AGX Xavier effective
    gs_flops: float = 220.0e12          # 8×RTX 3090 effective
    deploy_patches: int = 1024          # vision tokens at deployment scale
    deploy_text: int = 32
    conf_net_flops: float = 2.0e6       # g̃ stage, negligible but counted
    # raw downlink bytes per task, calibrated so GS-only/satellite-only
    # ratios match Fig. 4/9 (det ≈ 4.1×, tx ≈ 76–90 % of GS-only time):
    # RSVQA-LR / RESISC tiles at processed resolution, DOTA-like 2048² scenes
    task_bytes: Dict[str, float] = dataclasses.field(default_factory=lambda: {
        "vqa": 1024 * 1024 * 3.0, "cls": 1024 * 1024 * 3.0,
        "det": 2048 * 2048 * 3.0})

    def prompt_tokens(self) -> int:
        return self.deploy_patches + self.deploy_text

    def sat_prefill_s(self) -> float:
        return 2 * self.sat_params * self.prompt_tokens() / self.sat_flops

    def sat_decode_s(self, n_tokens: float) -> float:
        return 2 * self.sat_params * n_tokens / self.sat_flops

    def sat_encode_s(self) -> float:
        """Visual+text encoding only (stage-1 confidence runs after this)."""
        return 0.15 * self.sat_prefill_s()

    def conf_stage_s(self) -> float:
        return self.conf_net_flops / self.sat_flops

    def gs_infer_s(self, n_answer_tokens: float, kept_fraction: float = 1.0
                   ) -> float:
        """W^g prefill (scaled by surviving vision tokens) + decode."""
        toks = self.deploy_patches * kept_fraction + self.deploy_text
        return 2 * self.gs_params * (toks + n_answer_tokens) / self.gs_flops

    def full_bytes(self, task: str) -> float:
        return self.task_bytes[task]

    def tx_s(self, link: LinkModel, n_bytes: float) -> float:
        return link.tx_seconds(n_bytes, sample_jitter=False)


DEFAULT_LINK = LinkModel(jitter_sigma=0.0)
DEFAULT_PLAN = ContactPlan(alt_km=570.0, num_gs=1)
