"""Simi(·,·) metrics (§3.1.1) and the confidence-training target.

The confidence network regresses the realized satellite↔ground output
similarity cos(ŷ^s, ŷ^g) (Eq. 1 RHS); task quality is measured with the
task-appropriate Simi against ground truth: exact match for VQA/
classification, region-set IoU for detection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cosine(a: jax.Array, b: jax.Array, axis: int = -1,
           eps: float = 1e-8) -> jax.Array:
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    num = (af * bf).sum(axis)
    den = jnp.linalg.norm(af, axis=axis) * jnp.linalg.norm(bf, axis=axis)
    return num / jnp.maximum(den, eps)


def output_similarity(dist_s: jax.Array, dist_g: jax.Array) -> jax.Array:
    """cos(ŷ^s, ŷ^g) over answer distributions, per sample.

    dist_*: (B, L_ans, V) answer-token probability distributions.  Multi-token
    answers are compared position-wise then averaged (a smooth, bounded [0,1]
    target for the MSE in Eq. 1)."""
    sim = cosine(dist_s, dist_g, axis=-1)          # (B, L_ans)
    return sim.mean(-1)


def simi_exact(pred: jax.Array, label: jax.Array) -> jax.Array:
    """VQA / classification: 1 if equal (per sample)."""
    return (pred == label).astype(jnp.float32)


def simi_region_iou(pred_mask: jax.Array, true_mask: jax.Array) -> jax.Array:
    """Detection: IoU between predicted / true region sets (B, N_r) bool."""
    p = pred_mask.astype(jnp.float32)
    t = true_mask.astype(jnp.float32)
    inter = (p * t).sum(-1)
    union = jnp.maximum((jnp.maximum(p, t)).sum(-1), 1.0)
    return inter / union


def task_simi(task: str, pred, label):
    if task in ("vqa", "cls"):
        return simi_exact(pred, label)
    if task == "det":
        return simi_region_iou(pred, label)
    raise ValueError(task)
