"""End-to-end system assembly: train the two proxy LVLM tiers on synthetic
EO tasks, supervise the progressive confidence network on a small split
(paper: 5 % of train), and assemble SpaceVerse + all baselines.

This is the substrate for the Fig. 9–12 benchmarks, the examples, and the
integration tests.  Proxy scale keeps CPU runtimes sane; the latency ledger
is evaluated at deployment scale by ``LatencyModel`` (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.spaceverse_pair import proxy_pair
from repro.core import confidence as C
from repro.core import eo_adapter as EO
from repro.core.cascade import CascadeConfig, SpaceVerse, TierModel
from repro.core.latency import LatencyModel
from repro.core.similarity import output_similarity
from repro.data import synthetic
from repro.train import optimizer as O
from repro.train import trainer as TR

TASKS = ("vqa", "cls", "det")


@dataclasses.dataclass
class SystemBundle:
    sat: TierModel
    gs: TierModel
    adapter_cfg: EO.EOAdapterConfig
    conf_params: Any
    cascade_cfg: CascadeConfig
    latency: LatencyModel
    datasets: Dict[str, Dict[str, np.ndarray]]       # task → test data
    train_datasets: Dict[str, Dict[str, np.ndarray]]
    history: Dict[str, Any]

    def spaceverse(self, **overrides) -> SpaceVerse:
        cc = dataclasses.replace(self.cascade_cfg, **overrides) \
            if overrides else self.cascade_cfg
        return SpaceVerse(self.sat, self.gs, self.adapter_cfg,
                          self.conf_params, cc, self.latency)


# ---------------------------------------------------------------------------
# Proxy LVLM training
# ---------------------------------------------------------------------------

def _task_batch(adapter_cfg: EO.EOAdapterConfig, task: str,
                data: Dict[str, np.ndarray], idx: np.ndarray,
                dtype) -> Dict[str, jnp.ndarray]:
    """Teacher-forced batch with raw region pixels (projector trains e2e)."""
    images = jnp.asarray(data["images"][idx])
    prompts = jnp.asarray(data["prompts"][idx])
    answers = EO.answers_from_labels(adapter_cfg, task,
                                     jnp.asarray(data["labels"][idx]),
                                     jnp.asarray(data["region_rel"][idx]))
    regions = synthetic.regions_of(images, adapter_cfg.grid)
    b, r = regions.shape[:2]
    raw = regions.reshape(b, r, -1).astype(dtype)
    prompt = adapter_cfg.prompt_token(task, prompts)[:, None]
    l_ans = answers.shape[1]
    tokens = jnp.concatenate([prompt, answers[:, :-1]], axis=1)
    s_total = r + 1 + (l_ans - 1)
    targets = jnp.zeros((b, s_total), jnp.int32)
    mask = jnp.zeros((b, s_total), jnp.float32)
    targets = jax.lax.dynamic_update_slice(targets, answers, (0, r))
    mask = jax.lax.dynamic_update_slice(
        mask, jnp.ones_like(answers, jnp.float32), (0, r))
    return {"tokens": tokens, "raw_regions": raw,
            "targets": targets, "loss_mask": mask}


def train_proxy(backbone_cfg: ArchConfig, adapter_cfg: EO.EOAdapterConfig,
                train_data: Dict[str, Dict[str, np.ndarray]], *,
                steps: int = 300, batch_size: int = 16, lr: float = 3e-3,
                region_dropout: float = 0.2, seed: int = 0
                ) -> Tuple[Dict, List[float]]:
    """Multi-task training of one tier (patch projector + backbone).

    ``region_dropout`` randomly zeroes regions during training so inference
    on Eq. 3-filtered (partially masked/downsampled) images is
    in-distribution — the robustness the paper's pretrained LVLMs have."""
    key = jax.random.PRNGKey(seed)
    k_init, key = jax.random.split(key)
    params = EO.init_adapter(k_init, backbone_cfg, adapter_cfg)
    opt_cfg = O.OptConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                          total_steps=steps, weight_decay=0.0)
    opt_state = O.init_opt_state(params)

    from repro.models import transformer as T

    def loss_fn(params, batch):
        model_batch = {k: v for k, v in batch.items() if k != "raw_regions"}
        model_batch["patch_embeds"] = (
            batch["raw_regions"] @ params["patch_proj"])
        return T.loss_fn(params["backbone"], backbone_cfg, model_batch,
                         remat=False)

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, stats = O.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        return params, opt_state, loss

    losses = []
    tasks = [t for t in TASKS if t in train_data]
    dtype = params["patch_proj"].dtype
    for s in range(steps):
        task = tasks[s % len(tasks)]
        key, sub, kd = jax.random.split(key, 3)
        n = train_data[task]["images"].shape[0]
        idx = np.asarray(jax.random.permutation(sub, n)[:batch_size])
        batch = _task_batch(adapter_cfg, task, train_data[task], idx, dtype)
        if region_dropout > 0:
            keep = jax.random.uniform(
                kd, batch["raw_regions"].shape[:2]) >= region_dropout
            batch["raw_regions"] = batch["raw_regions"] * \
                keep[..., None].astype(dtype)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
    return params, losses


# ---------------------------------------------------------------------------
# Confidence-net supervision (§3.1.4)
# ---------------------------------------------------------------------------

def build_confidence_data(sat: TierModel, gs: TierModel,
                          adapter_cfg: EO.EOAdapterConfig,
                          data: Dict[str, np.ndarray], task: str,
                          answer_vocab: int, batch_size: int = 32):
    """Run both tiers on the supervision split; target = cos(ŷ^s, ŷ^g)."""
    n = data["images"].shape[0]
    vis, states, tgts = [], [], []
    for i in range(0, n, batch_size):
        sl = slice(i, min(i + batch_size, n))
        images = jnp.asarray(data["images"][sl])
        prompts = jnp.asarray(data["prompts"][sl])
        rf = EO.encode_regions(sat.params, adapter_cfg, images)
        vis.append(np.asarray(rf.astype(jnp.float32).mean(1)))
        s_toks, s_probs = EO.generate(sat.params, sat.cfg, adapter_cfg, task,
                                      images, prompts, answer_vocab)
        g_toks, g_probs = EO.generate(gs.params, gs.cfg, adapter_cfg, task,
                                      images, prompts, answer_vocab)
        states.append(np.asarray(EO.token_features(sat.params, s_toks)))
        tgts.append(np.asarray(output_similarity(s_probs, g_probs)))
    return (np.concatenate(vis), np.concatenate(states),
            np.concatenate(tgts))


def train_confidence_net(sat: TierModel, gs: TierModel,
                         adapter_cfg: EO.EOAdapterConfig,
                         train_data: Dict[str, Dict[str, np.ndarray]],
                         answer_vocab: int, *, frac: float = 0.05,
                         num_stages: int = 2, steps: int = 400,
                         seed: int = 0):
    vis_all, st_all, tgt_all = [], [], []
    for task, data in train_data.items():
        n = max(int(data["images"].shape[0] * frac), 16)
        sub = {k: v[:n] for k, v in data.items() if isinstance(v, np.ndarray)}
        v, s, t = build_confidence_data(sat, gs, adapter_cfg, sub, task,
                                        answer_vocab)
        vis_all.append(v)
        st_all.append(s)
        tgt_all.append(t)
    vis = jnp.asarray(np.concatenate(vis_all))
    st = jnp.asarray(np.concatenate(st_all))
    tgt = jnp.asarray(np.concatenate(tgt_all))
    d_visual, d_state = vis.shape[-1], st.shape[-1]
    conf = C.init_confidence(jax.random.PRNGKey(seed), d_visual, d_state,
                             hidden=64, num_stages=num_stages)
    # stages 2..I all see pooled generated-token features
    states = [st] * (num_stages - 1)
    conf, losses = C.train_confidence(conf, vis, states, tgt, steps=steps,
                                      seed=seed)
    return conf, losses


# ---------------------------------------------------------------------------
# Full assembly
# ---------------------------------------------------------------------------

def build_system(*, scale: str = "small", n_train: int = 256,
                 n_test: int = 128, proxy_steps: int = 250,
                 conf_steps: int = 300, seed: int = 0,
                 tasks: Tuple[str, ...] = TASKS,
                 grid: int = 4, image_size: int = 64,
                 cascade_cfg: Optional[CascadeConfig] = None
                 ) -> SystemBundle:
    sat_cfg, gs_cfg = proxy_pair(scale)
    adapter_cfg = EO.EOAdapterConfig(grid=grid, image_size=image_size)
    eo_cfg = synthetic.EOTaskConfig(image_size=image_size, grid=grid,
                                    num_classes=adapter_cfg.num_classes)
    train_data = {t: synthetic.make_dataset(t, n_train, seed=seed + i,
                                            cfg=eo_cfg)
                  for i, t in enumerate(tasks)}
    test_data = {t: synthetic.make_dataset(t, n_test, seed=seed + 100 + i,
                                           cfg=eo_cfg)
                 for i, t in enumerate(tasks)}

    cc = cascade_cfg or CascadeConfig(
        answer_vocab=max(adapter_cfg.num_classes + 1, 2))

    sat_params, sat_losses = train_proxy(sat_cfg, adapter_cfg, train_data,
                                         steps=proxy_steps, seed=seed)
    # the GS tier trains longer at a gentler LR (deeper model), preserving the
    # paper's |W^g| > |W^s| quality ordering
    gs_params, gs_losses = train_proxy(gs_cfg, adapter_cfg, train_data,
                                       steps=int(proxy_steps * 1.5),
                                       lr=2e-3, seed=seed + 1)
    sat = TierModel(sat_params, sat_cfg)
    gs = TierModel(gs_params, gs_cfg)

    conf, conf_losses = train_confidence_net(
        sat, gs, adapter_cfg, train_data, cc.answer_vocab,
        num_stages=len(cc.taus), steps=conf_steps, seed=seed)

    return SystemBundle(
        sat=sat, gs=gs, adapter_cfg=adapter_cfg, conf_params=conf,
        cascade_cfg=cc, latency=LatencyModel(),
        datasets=test_data, train_datasets=train_data,
        history={"sat_losses": sat_losses, "gs_losses": gs_losses,
                 "conf_losses": conf_losses})
