"""SpaceVerse cascade orchestrator — Algorithm 1 (batch-evaluator adapter).

Per input (x_k, T_k):
 1. encode regions V(x_k) and prompt E(T_k) with the onboard model W^s;
 2. progressive confidence: stage 1 from pooled V(x) alone; stages i>1 after
    each additional chunk of N_t generated tokens; a score below τ_i aborts
    onboard decoding and offloads;
 3. offloaded samples pass Eq. (2) region scoring + Eq. (3) multi-scale
    preprocessing, transit the simulated link, and are answered by W^g;
 4. surviving samples answer onboard.

The model execution itself lives in ONE place — the shared
``serving.executor.CascadeExecutor`` driven by a
``ProgressiveConfidencePolicy`` — which the request-level
``serving.cascade_server.CascadeServer`` also routes through, so the batch
evaluator and the server can never drift (DESIGN.md §serving).  This class
is the counterfactual-evaluation adapter: the whole batch path is
vectorised, decisions are boolean masks, both branches are computed, and the
latency ledger charges each sample only for the branch it actually took (the
physical system runs one branch; the simulator runs both to know the
counterfactual).  Accuracy comes from the really-executed proxy models;
per-sample latency from ``LatencyModel`` evaluated at the paper's deployment
pair (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import eo_adapter as EO
from repro.core.latency import LatencyModel, DEFAULT_LINK
from repro.core.similarity import task_simi
from repro.network.link import LinkModel

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    taus: Tuple[float, ...] = (0.5, 0.4)      # τ_1..τ_I (paper §4.1.4)
    alpha: float = 0.35
    beta: float = 0.55
    n_t: int = 8                               # tokens per progressive chunk
    answer_vocab: int = 64


@dataclasses.dataclass
class TierModel:
    params: Params
    cfg: ArchConfig


class SpaceVerse:
    """Two-tier cascade with progressive confidence + multi-scale preprocess."""

    def __init__(self, sat: TierModel, gs: TierModel,
                 adapter_cfg: EO.EOAdapterConfig, conf_params: Params,
                 cascade_cfg: Optional[CascadeConfig] = None,
                 latency: Optional[LatencyModel] = None,
                 link: LinkModel = DEFAULT_LINK):
        self.sat = sat
        self.gs = gs
        self.adapter_cfg = adapter_cfg
        self.conf = conf_params
        self.cc = cascade_cfg or CascadeConfig()
        self.lat = latency or LatencyModel()
        self.link = link

    # ------------------------------------------------------------------
    def _pipeline(self):
        from repro.serving.offload import OffloadPipeline
        return OffloadPipeline(self.adapter_cfg, self.cc, self.lat,
                               link=self.link)

    def _executor(self, pipeline):
        from repro.serving.engine_core import shared_core
        from repro.serving.executor import CascadeExecutor
        return CascadeExecutor(shared_core(self.sat, self.adapter_cfg),
                               shared_core(self.gs, self.adapter_cfg),
                               self.adapter_cfg, pipeline)

    def _policy(self):
        from repro.serving.policy import ProgressiveConfidencePolicy
        return ProgressiveConfidencePolicy(self.conf, self.cc)

    def _stage_plan(self, task: str) -> Sequence[int]:
        """Token counts decoded before confidence stages 2..I (the last stage
        always sees the complete output)."""
        return self._policy().stage_plan(task,
                                         self.adapter_cfg.answer_len(task))

    # ------------------------------------------------------------------
    def run_batch(self, task: str, images: jax.Array, prompts: jax.Array
                  ) -> Dict[str, Any]:
        lat = self.lat
        b = images.shape[0]
        l_ans = self.adapter_cfg.answer_len(task)

        pipeline = self._pipeline()
        res = self._executor(pipeline).run_counterfactual(
            self._policy(), task, images, prompts, self.cc.answer_vocab)

        view = res.gs_view
        # modelled raw-image bytes scaled by the achieved Eq. 3 compression
        tx_bytes = pipeline.payload_bytes(task, view.bytes_frac)    # (B,)
        kept_frac = view.kept_frac

        # --- latency ledger ------------------------------------------------
        plan = res.stage_plan
        lat_s = np.full((b,), lat.sat_encode_s() + lat.conf_stage_s())
        exit_np = np.asarray(res.exit_stage)
        # onboard decode cost: tokens decoded before this sample's exit
        toks_before = np.zeros((b,))
        for si in range(len(plan)):
            ran_chunk = (exit_np < 0) | (exit_np >= si + 1)
            toks_before += np.where(ran_chunk, plan[si], 0)
        ran_prefill = exit_np != 0
        lat_s += ran_prefill * lat.sat_prefill_s()
        lat_s += lat.sat_decode_s(toks_before)
        lat_s += np.maximum(exit_np, 0) * lat.conf_stage_s()
        tx_s = np.array([pipeline.transmit_analytic(byt)
                         for byt in tx_bytes])
        gs_s = np.asarray(lat.gs_infer_s(l_ans, np.asarray(kept_frac)))
        lat_s += np.asarray(res.offload) * (tx_s + gs_s)

        return {
            "pred": res.pred, "offload": res.offload,
            "exit_stage": res.exit_stage,
            "conf_scores": res.conf_scores,
            "sat_pred": res.sat_pred, "gs_pred": res.gs_pred,
            "sat_probs": res.sat_probs, "gs_probs": res.gs_probs,
            "tx_bytes": tx_bytes, "latency_s": lat_s,
            "kept_frac": np.asarray(kept_frac),
            "region_scores": view.region_scores,
        }

    # ------------------------------------------------------------------
    def evaluate(self, task: str, data: Dict[str, np.ndarray],
                 batch_size: int = 32) -> Dict[str, Any]:
        n = data["images"].shape[0]
        outs = []
        for i in range(0, n, batch_size):
            sl = slice(i, min(i + batch_size, n))
            outs.append(self.run_batch(task, jnp.asarray(data["images"][sl]),
                                       jnp.asarray(data["prompts"][sl])))
        pred = np.concatenate([np.asarray(o["pred"]) for o in outs])
        lat_s = np.concatenate([o["latency_s"] for o in outs])
        off = np.concatenate([np.asarray(o["offload"]) for o in outs])
        label = (data["region_rel"] if task == "det" else data["labels"])[:n]
        simi = np.asarray(task_simi(task, jnp.asarray(pred),
                                    jnp.asarray(label)))
        return {"performance": float(simi.mean()),
                "latency_s": float(lat_s.mean()),
                "offload_rate": float(off.mean()),
                "per_sample_latency": lat_s, "per_sample_simi": simi,
                "offload": off}
