"""SpaceVerse cascade orchestrator — Algorithm 1.

Per input (x_k, T_k):
 1. encode regions V(x_k) and prompt E(T_k) with the onboard model W^s;
 2. progressive confidence: stage 1 from pooled V(x) alone; stages i>1 after
    each additional chunk of N_t generated tokens; a score below τ_i aborts
    onboard decoding and offloads;
 3. offloaded samples pass Eq. (2) region scoring + Eq. (3) multi-scale
    preprocessing, transit the simulated link, and are answered by W^g;
 4. surviving samples answer onboard.

Accuracy comes from the really-executed proxy models; per-sample latency from
``LatencyModel`` evaluated at the paper's deployment pair (DESIGN.md §7).
The whole batch path is vectorised — decisions are boolean masks, so both
branches are computed and the latency ledger charges each sample only for the
branch it actually took (the physical system runs one branch; the simulator
runs both to know the counterfactual).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import confidence as C
from repro.core import eo_adapter as EO
from repro.core import preprocess as PP
from repro.core import region_attention as RA
from repro.core.latency import LatencyModel, DEFAULT_LINK
from repro.core.similarity import task_simi
from repro.data import synthetic
from repro.network.link import LinkModel

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    taus: Tuple[float, ...] = (0.5, 0.4)      # τ_1..τ_I (paper §4.1.4)
    alpha: float = 0.35
    beta: float = 0.55
    n_t: int = 8                               # tokens per progressive chunk
    answer_vocab: int = 64


@dataclasses.dataclass
class TierModel:
    params: Params
    cfg: ArchConfig


class SpaceVerse:
    """Two-tier cascade with progressive confidence + multi-scale preprocess."""

    def __init__(self, sat: TierModel, gs: TierModel,
                 adapter_cfg: EO.EOAdapterConfig, conf_params: Params,
                 cascade_cfg: CascadeConfig = CascadeConfig(),
                 latency: LatencyModel = LatencyModel(),
                 link: LinkModel = DEFAULT_LINK):
        self.sat = sat
        self.gs = gs
        self.adapter_cfg = adapter_cfg
        self.conf = conf_params
        self.cc = cascade_cfg
        self.lat = latency
        self.link = link

    # ------------------------------------------------------------------
    def _stage_plan(self, task: str) -> Sequence[int]:
        """Token counts decoded before confidence stages 2..I (the last stage
        always sees the complete output)."""
        l_ans = self.adapter_cfg.answer_len(task)
        n_stages = C.num_stages(self.conf)
        if n_stages <= 1:
            return []
        chunks = []
        done = 0
        for i in range(n_stages - 2):
            c = min(self.cc.n_t, l_ans - done)
            chunks.append(max(c, 0))
            done += c
        chunks.append(max(l_ans - done, 0))   # final stage: complete output
        return chunks

    # ------------------------------------------------------------------
    def run_batch(self, task: str, images: jax.Array, prompts: jax.Array
                  ) -> Dict[str, Any]:
        ac, cc, lat = self.adapter_cfg, self.cc, self.lat
        b = images.shape[0]
        l_ans = ac.answer_len(task)

        # --- onboard encoders (V, E) --------------------------------------
        region_feats = EO.encode_regions(self.sat.params, ac, images)  # (B,R,d)
        text_feats = EO.encode_text(self.sat.params, self.sat.cfg,
                                    ac.prompt_token(task, prompts))    # (B,1,d)
        visual_pooled = region_feats.astype(jnp.float32).mean(axis=1)

        # --- progressive confidence + chunked onboard decode ---------------
        scores = [C.apply_stage(self.conf, 0, visual_pooled)]
        offload = scores[0] < cc.taus[0]              # aborted before decode
        exit_stage = jnp.where(offload, 0, -1)        # -1 = still running

        logits, cache, idx = EO.prefill_prompt(
            self.sat.params, self.sat.cfg, ac, task, images, prompts, l_ans)
        toks_all, probs_all = [], []
        decoded = 0
        for si, n_tok in enumerate(self._stage_plan(task)):
            if n_tok > 0:
                toks, probs, cache, logits, idx = EO.decode_chunk(
                    self.sat.params, self.sat.cfg, cache, logits, idx, n_tok,
                    cc.answer_vocab)
                toks_all.append(toks)
                probs_all.append(probs)
                decoded += n_tok
            gen = jnp.concatenate(toks_all, 1)
            state = EO.token_features(self.sat.params, gen)
            s = C.apply_stage(self.conf, si + 1, visual_pooled, state)
            scores.append(s)
            tau = cc.taus[min(si + 1, len(cc.taus) - 1)]
            newly = (s < tau) & (exit_stage < 0)
            exit_stage = jnp.where(newly, si + 1, exit_stage)
            offload = offload | newly

        sat_tokens = (jnp.concatenate(toks_all, 1) if toks_all
                      else jnp.zeros((b, l_ans), jnp.int32))
        sat_probs = (jnp.concatenate(probs_all, 1) if probs_all
                     else jnp.zeros((b, l_ans, cc.answer_vocab)))
        sat_pred = EO.prediction_from_tokens(task, sat_tokens)

        # --- Eq. 2 + Eq. 3 preprocessing for offloaded samples -------------
        regions_px = synthetic.regions_of(images, ac.grid)
        _, norm_scores = RA.score_regions(region_feats[:, :, None, :],
                                          text_feats)
        filtered, tx_bytes_regions, meta = PP.multiscale_filter(
            regions_px, norm_scores, alpha=cc.alpha, beta=cc.beta)
        gs_images = synthetic.assemble(filtered, ac.grid)
        kept_frac = 1.0 - meta["discarded"].mean(-1)

        # scale modelled raw-image bytes by the achieved compression
        full_bytes = lat.full_bytes(task)
        comp = np.asarray(tx_bytes_regions) / np.maximum(
            np.asarray(meta["full_bytes"]), 1.0)
        tx_bytes = full_bytes * comp                              # (B,)

        # --- GS inference on preprocessed images ---------------------------
        gs_tokens, gs_probs = EO.generate(self.gs.params, self.gs.cfg, ac,
                                          task, gs_images, prompts,
                                          cc.answer_vocab)
        gs_pred = EO.prediction_from_tokens(task, gs_tokens)

        # --- merge ----------------------------------------------------------
        off_np = np.asarray(offload)
        if task == "det":
            pred = jnp.where(offload[:, None], gs_pred, sat_pred)
        else:
            pred = jnp.where(offload, gs_pred, sat_pred)

        # --- latency ledger --------------------------------------------------
        plan = self._stage_plan(task)
        lat_s = np.full((b,), lat.sat_encode_s() + lat.conf_stage_s())
        exit_np = np.asarray(exit_stage)
        # onboard decode cost: tokens decoded before this sample's exit
        toks_before = np.zeros((b,))
        for si in range(len(plan)):
            ran_chunk = (exit_np < 0) | (exit_np >= si + 1)
            toks_before += np.where(ran_chunk, plan[si], 0)
        ran_prefill = exit_np != 0
        lat_s += ran_prefill * lat.sat_prefill_s()
        lat_s += lat.sat_decode_s(toks_before)
        lat_s += np.maximum(exit_np, 0) * lat.conf_stage_s()
        tx_s = np.array([lat.tx_s(self.link, byt) for byt in tx_bytes])
        gs_s = np.asarray(lat.gs_infer_s(l_ans, np.asarray(kept_frac)))
        lat_s += off_np * (tx_s + gs_s)

        return {
            "pred": pred, "offload": offload, "exit_stage": exit_stage,
            "conf_scores": jnp.stack(scores, 1),
            "sat_pred": sat_pred, "gs_pred": gs_pred,
            "sat_probs": sat_probs, "gs_probs": gs_probs,
            "tx_bytes": tx_bytes, "latency_s": lat_s,
            "kept_frac": np.asarray(kept_frac),
            "region_scores": norm_scores,
        }

    # ------------------------------------------------------------------
    def evaluate(self, task: str, data: Dict[str, np.ndarray],
                 batch_size: int = 32) -> Dict[str, Any]:
        n = data["images"].shape[0]
        outs = []
        for i in range(0, n, batch_size):
            sl = slice(i, min(i + batch_size, n))
            outs.append(self.run_batch(task, jnp.asarray(data["images"][sl]),
                                       jnp.asarray(data["prompts"][sl])))
        pred = np.concatenate([np.asarray(o["pred"]) for o in outs])
        lat_s = np.concatenate([o["latency_s"] for o in outs])
        off = np.concatenate([np.asarray(o["offload"]) for o in outs])
        label = (data["region_rel"] if task == "det" else data["labels"])[:n]
        simi = np.asarray(task_simi(task, jnp.asarray(pred),
                                    jnp.asarray(label)))
        return {"performance": float(simi.mean()),
                "latency_s": float(lat_s.mean()),
                "offload_rate": float(off.mean()),
                "per_sample_latency": lat_s, "per_sample_simi": simi,
                "offload": off}
