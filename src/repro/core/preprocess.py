"""Attention-guided multi-scale preprocessing — Eq. (3) (§3.2.3).

              ⎧ 0                          K(x^r) < α        (discard)
  f(x^r)  =   ⎨ D(x^r, (β−α)/(K−α))        α ≤ K(x^r) < β    (downsample)
              ⎩ x^r                        β ≤ K(x^r)        (preserve)

The paper's scaling factor c = (β−α)/(K−α) ≥ 1 shrinks each spatial side by
c.  JAX needs static shapes, so c is quantised to a pyramid of power-of-two
pooling levels; the "transmitted" tensor keeps full layout with each region
replaced by its pooled-then-nearest-upsampled reconstruction (zero if
discarded) — information loss and byte accounting are exact per level.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp


def _avg_pool(regions: jax.Array, f: int) -> jax.Array:
    """(B, R, h, w, C) average-pool by factor f then nearest-upsample back."""
    if f == 1:
        return regions
    b, r, h, w, c = regions.shape
    x = regions.reshape(b, r, h // f, f, w // f, f, c).mean(axis=(3, 5))
    x = jnp.repeat(jnp.repeat(x, f, axis=2), f, axis=3)
    return x


def scale_factor(scores: jax.Array, alpha: float, beta: float) -> jax.Array:
    """Paper's c = (β−α)/(K−α) on the downsample band, ∞ below α, 1 above β."""
    c = (beta - alpha) / jnp.maximum(scores - alpha, 1e-9)
    return jnp.where(scores >= beta, 1.0,
                     jnp.where(scores < alpha, jnp.inf, jnp.maximum(c, 1.0)))


def multiscale_filter(regions: jax.Array, scores: jax.Array, *,
                      alpha: float = 0.35, beta: float = 0.55,
                      levels: Sequence[int] = (1, 2, 4, 8),
                      bytes_per_px: float = 3.0
                      ) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """regions: (B, R, h, w, C); scores: (B, R) normalised K(x^r).

    Returns (filtered regions, tx_bytes (B,), meta).  ``tx_bytes`` counts
    h·w·C/ c² per kept region (c = selected pooling level), zero if dropped.
    """
    b, r, h, w, ch = regions.shape
    c = scale_factor(scores, alpha, beta)                     # (B, R)
    # quantise c to the pyramid: pick the smallest level ≥ c (most faithful
    # resolution that still meets the paper's compression target)
    lv = jnp.asarray(levels, jnp.float32)
    # level index: number of levels strictly below c, clipped
    li = jnp.clip(jnp.sum(lv[None, None, :] < c[..., None], axis=-1),
                  0, len(levels) - 1)                         # (B, R) int
    discard = scores < alpha

    pyramid = jnp.stack([_avg_pool(regions, f) for f in levels], axis=0)
    sel = jnp.take_along_axis(
        pyramid, li[None, ..., None, None, None].astype(jnp.int32),
        axis=0)[0]
    out = jnp.where(discard[..., None, None, None], 0.0, sel)

    level_vals = jnp.take(lv, li)
    px = (h * w * ch) / (level_vals ** 2)
    tx_bytes = jnp.where(discard, 0.0, px * bytes_per_px).sum(-1)  # (B,)
    full_bytes = float(r * h * w * ch * bytes_per_px)
    meta = {
        "levels": level_vals,
        "discarded": discard,
        "compression_ratio": full_bytes / jnp.maximum(tx_bytes, 1.0),
        "full_bytes": jnp.full((b,), full_bytes),
    }
    return out, tx_bytes, meta


def random_mask_filter(regions: jax.Array, keep_frac: float, key: jax.Array,
                       *, bytes_per_px: float = 3.0):
    """GS-only baseline redundancy reduction (Fig. 3/12): random region drop."""
    b, r = regions.shape[:2]
    keep = jax.random.uniform(key, (b, r)) < keep_frac
    out = jnp.where(keep[..., None, None, None], regions, 0.0)
    px = regions.shape[2] * regions.shape[3] * regions.shape[4]
    tx_bytes = keep.sum(-1).astype(jnp.float32) * px * bytes_per_px
    return out, tx_bytes, {"kept": keep}
