"""Text-image attention over regions — Eq. (2), kernel-backed (§3.2.2).

``K(x^r) = Σ_i Σ_j cos(V_i(x^r), E_j(T_k))`` computed by the
``region_score`` Pallas kernel (TPU) / jnp oracle (CPU).  The raw score is
unbounded (it scales with N_V·N_E), so ``score_regions`` also returns the
per-image **normalised** score used against the paper's thresholds
(α=0.35, β=0.55): mean cosine mapped from [−1, 1] to [0, 1].
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops


def score_regions(region_feats: jax.Array, text_feats: jax.Array,
                  *, impl=None) -> Tuple[jax.Array, jax.Array]:
    """region_feats: (B, R, Nv, D) V(x^r); text_feats: (B, Ne, D) E(T).

    Returns (raw (B, R), normalised (B, R) in [0, 1])."""
    raw = ops.region_score(region_feats, text_feats, impl=impl)
    nv, ne = region_feats.shape[2], text_feats.shape[1]
    mean_cos = raw / float(nv * ne)            # [−1, 1]
    return raw, jnp.clip(0.5 * (mean_cos + 1.0), 0.0, 1.0)
