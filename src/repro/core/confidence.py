"""Progressive confidence network g̃ (§3.1).

A shared MLP trunk ``M`` with ``I`` stage-specific input projections
``{L_i}``: stage 1 scores from pooled visual features V(x) alone (before any
decode step); stage i>1 additionally sees the pooled hidden states of the
(i−1)·N_t tokens generated so far.  g̃_i = [L_i; M] predicts the
satellite↔ground output similarity; a sample whose score falls below τ_i is
offloaded and onboard decoding is aborted (early-exit — the latency win of g
combined with the robustness of g′, Fig. 6).

Training (Eq. 1): Σ_i MSE(g̃_i(V(x), A_{i−1}), cos(ŷ^s, ŷ^g)), supervised on
a held-out split where both tiers were run.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def init_confidence(key: jax.Array, d_visual: int, d_state: int,
                    hidden: int = 128, num_stages: int = 2) -> Params:
    """L_1: d_visual → hidden;  L_i (i>1): d_visual + d_state → hidden;
    trunk M: hidden → hidden → 1."""
    ks = jax.random.split(key, num_stages + 2)
    projs = []
    for i in range(num_stages):
        d_in = d_visual if i == 0 else d_visual + d_state
        w = jax.random.normal(ks[i], (d_in, hidden)) * (d_in ** -0.5)
        projs.append({"w": w.astype(jnp.float32),
                      "b": jnp.zeros((hidden,), jnp.float32)})
    m1 = jax.random.normal(ks[-2], (hidden, hidden)) * (hidden ** -0.5)
    m2 = jax.random.normal(ks[-1], (hidden, 1)) * (hidden ** -0.5)
    return {
        "projs": projs,
        "trunk": {"w1": m1.astype(jnp.float32),
                  "b1": jnp.zeros((hidden,), jnp.float32),
                  "w2": m2.astype(jnp.float32),
                  "b2": jnp.zeros((1,), jnp.float32)},
    }


def num_stages(params: Params) -> int:
    return len(params["projs"])


def apply_stage(params: Params, stage: int, visual: jax.Array,
                state: jax.Array | None = None) -> jax.Array:
    """g̃_{stage+1}.  visual: (B, d_visual) pooled V(x); state: (B, d_state)
    pooled hidden of the tokens generated so far (None for stage 0).
    Returns (B,) predicted similarity in [0, 1]."""
    x = visual.astype(jnp.float32)
    if stage > 0:
        assert state is not None, "stage>0 needs generated-token features"
        x = jnp.concatenate([x, state.astype(jnp.float32)], axis=-1)
    p = params["projs"][stage]
    h = jax.nn.relu(x @ p["w"] + p["b"])
    t = params["trunk"]
    h = jax.nn.relu(h @ t["w1"] + t["b1"])
    return jax.nn.sigmoid((h @ t["w2"] + t["b2"])[..., 0])


def loss_fn(params: Params, visual: jax.Array,
            states: Sequence[jax.Array], target: jax.Array) -> jax.Array:
    """Eq. (1): Σ_i MSE(g̃_i(·), cos-sim target).  states[i] is the pooled
    token features available to stage i+1 (len = num_stages − 1)."""
    total = jnp.mean((apply_stage(params, 0, visual) - target) ** 2)
    for i, st in enumerate(states):
        pred = apply_stage(params, i + 1, visual, st)
        total = total + jnp.mean((pred - target) ** 2)
    return total


def train_confidence(params: Params, visual: jax.Array,
                     states: Sequence[jax.Array], target: jax.Array, *,
                     steps: int = 300, lr: float = 1e-2,
                     batch: int = 64, seed: int = 0
                     ) -> Tuple[Params, List[float]]:
    """Adam on Eq. (1) over a small supervision split (paper: 5% of train)."""
    n = visual.shape[0]
    opt = {"m": jax.tree.map(jnp.zeros_like, params),
           "v": jax.tree.map(jnp.zeros_like, params)}
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def step(params, opt, idx, t):
        vis = visual[idx]
        sts = [s[idx] for s in states]
        tgt = target[idx]
        loss, grads = jax.value_and_grad(loss_fn)(params, vis, sts, tgt)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"],
                         grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
        params = jax.tree.map(
            lambda p, mh_, vh_: p - lr * mh_ / (jnp.sqrt(vh_) + eps),
            params, mh, vh)
        return params, {"m": m, "v": v}, loss

    losses = []
    for t in range(1, steps + 1):
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (min(batch, n),), 0, n)
        params, opt, loss = step(params, opt, idx, jnp.float32(t))
        losses.append(float(loss))
    return params, losses
