"""SpaceVerse core: the paper's contribution as composable JAX modules.

- ``confidence``        progressive confidence network g̃ (§3.1)
- ``region_attention``  Eq. (2) text-image region scoring (kernel-backed)
- ``preprocess``        Eq. (3) multi-scale filter + byte accounting
- ``cascade``           Algorithm 1 orchestrator (two-tier inference)
- ``eo_adapter``        LVLM task protocol for EO tasks
- ``similarity``        Simi metrics + confidence targets
- ``latency``           paper-calibrated deployment latency model
"""
from repro.core import (cascade, confidence, eo_adapter, latency,  # noqa: F401
                        preprocess, region_attention, similarity)
