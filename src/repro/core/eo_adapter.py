"""EO task adapter: wraps a backbone into the paper's LVLM task protocol.

The satellite/GS LVLMs answer Earth-observation prompts autoregressively over
a shared sequence layout:

    [ R region tokens | prompt token | answer tokens ]

- region tokens: one visual token per image region — a learned linear patch
  projector over raw region pixels (the stubbed "visual encoder V"),
- prompt token: task/class id embedded with the backbone's token table (the
  "text encoder E" — same feature space as V, exactly as §3.2.2 requires),
- answers: VQA → 1 yes/no token; classification → 1 class token;
  detection → N_r per-region yes/no tokens (multi-token, which is what the
  progressive confidence stages chunk over).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data import synthetic
from repro.models import transformer as T

Params = Dict[str, Any]

YES, NO = 1, 0  # answer token ids


@dataclasses.dataclass(frozen=True)
class EOAdapterConfig:
    grid: int = 4                       # N_r = grid² regions
    image_size: int = 64
    channels: int = 3
    num_classes: int = 8

    @property
    def n_regions(self) -> int:
        return self.grid * self.grid

    @property
    def patch_dim(self) -> int:
        side = self.image_size // self.grid
        return side * side * self.channels

    def answer_len(self, task: str) -> int:
        return self.n_regions if task == "det" else 1

    def prompt_token(self, task: str, prompts: jax.Array) -> jax.Array:
        """Disjoint prompt-id ranges per task (T_k must identify the task):
        vqa → [0, C); cls → C; det → [C+1, 2C+1)."""
        c = self.num_classes
        p = prompts.astype(jnp.int32)
        if task == "vqa":
            return p
        if task == "cls":
            return jnp.full_like(p, c)
        if task == "det":
            return c + 1 + p
        raise ValueError(task)

    def prompt_id(self, task: str, prompt: int) -> int:
        """Scalar host-side ``prompt_token`` for the admission hot path —
        same vocabulary layout, no device roundtrip (a test pins the two
        against each other)."""
        c = self.num_classes
        if task == "vqa":
            return int(prompt)
        if task == "cls":
            return c
        if task == "det":
            return c + 1 + int(prompt)
        raise ValueError(task)


def init_adapter(key: jax.Array, backbone_cfg: ArchConfig,
                 adapter_cfg: EOAdapterConfig) -> Params:
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (adapter_cfg.patch_dim, backbone_cfg.d_model))
    return {
        "backbone": T.init_params(backbone_cfg, k2),
        "patch_proj": (w * adapter_cfg.patch_dim ** -0.5).astype(
            jnp.dtype(backbone_cfg.dtype)),
    }


# ---------------------------------------------------------------------------
# Encoders (the paper's V and E)
# ---------------------------------------------------------------------------

def encode_regions(params: Params, adapter_cfg: EOAdapterConfig,
                   images: jax.Array) -> jax.Array:
    """V(x^r): (B, H, W, C) → (B, R, d) one visual token per region."""
    regions = synthetic.regions_of(images, adapter_cfg.grid)
    b, r = regions.shape[:2]
    flat = regions.reshape(b, r, -1).astype(params["patch_proj"].dtype)
    return flat @ params["patch_proj"]


def encode_text(params: Params, backbone_cfg: ArchConfig,
                prompt_tokens: jax.Array) -> jax.Array:
    """E(T): (B,) prompt ids → (B, 1, d) text features."""
    tok = params["backbone"]["embed"]["tok"]
    return jnp.take(tok, prompt_tokens, axis=0)[:, None, :]


def token_features(params: Params, tokens: jax.Array) -> jax.Array:
    """Pooled embedding of generated tokens A_i: (B, L) ids → (B, d)."""
    tok = params["backbone"]["embed"]["tok"]
    return jnp.take(tok, tokens, axis=0).astype(jnp.float32).mean(axis=1)


# ---------------------------------------------------------------------------
# Training batches
# ---------------------------------------------------------------------------

def build_batch(params: Params, backbone_cfg: ArchConfig,
                adapter_cfg: EOAdapterConfig, task: str,
                images: jax.Array, prompts: jax.Array,
                answers: jax.Array) -> Dict[str, jax.Array]:
    """answers: (B, L_ans) int32 — supervised answer tokens."""
    b = images.shape[0]
    r = adapter_cfg.n_regions
    l_ans = answers.shape[1]
    patch_embeds = encode_regions(params, adapter_cfg, images)
    prompt = adapter_cfg.prompt_token(task, prompts)[:, None]
    # input text tokens: [prompt, ans_0 .. ans_{L-2}] — teacher forcing
    tokens = jnp.concatenate([prompt, answers[:, :-1]], axis=1)
    s_total = r + 1 + (l_ans - 1)
    targets = jnp.zeros((b, s_total), jnp.int32)
    mask = jnp.zeros((b, s_total), jnp.float32)
    targets = jax.lax.dynamic_update_slice(targets, answers, (0, r))
    mask = jax.lax.dynamic_update_slice(mask, jnp.ones_like(answers,
                                                            jnp.float32),
                                        (0, r))
    return {"tokens": tokens, "patch_embeds": patch_embeds,
            "targets": targets, "loss_mask": mask}


def answers_from_labels(adapter_cfg: EOAdapterConfig, task: str,
                        labels: jax.Array,
                        region_rel: Optional[jax.Array] = None) -> jax.Array:
    if task == "vqa":
        return labels[:, None].astype(jnp.int32)           # 0/1
    if task == "cls":
        return labels[:, None].astype(jnp.int32)           # class id
    if task == "det":
        assert region_rel is not None
        return region_rel.astype(jnp.int32)                # (B, R) 0/1
    raise ValueError(task)


# ---------------------------------------------------------------------------
# Inference: chunked greedy generation (the progressive-confidence substrate)
# ---------------------------------------------------------------------------

def prefill_tokens(params: Params, backbone_cfg: ArchConfig,
                   adapter_cfg: EOAdapterConfig, images: jax.Array,
                   prompt_tokens: jax.Array, max_len: int
                   ) -> Tuple[jax.Array, Tuple, jax.Array]:
    """Prefill [regions | prompt] from already-converted prompt token ids
    (the jit-friendly primitive: no task-string branching inside)."""
    patch_embeds = encode_regions(params, adapter_cfg, images)
    inputs = {"tokens": prompt_tokens[:, None], "patch_embeds": patch_embeds}
    return T.prefill(params["backbone"], backbone_cfg, inputs, max_len)


def prefill_regions(params: Params, backbone_cfg: ArchConfig,
                    adapter_cfg: EOAdapterConfig, images: jax.Array,
                    max_len: int) -> Tuple[jax.Array, Tuple, jax.Array]:
    """Prefill the **scene prefix** only — the R region tokens, no prompt.

    The region tokens are the prompt-independent prefix of every request
    over the same captured scene (causal attention: their KV and the
    recurrent state after them cannot depend on the later prompt token), so
    the paged engine prefills them once per scene and shares the resulting
    KV pages read-only across all queries that fan out over the scene."""
    patch_embeds = encode_regions(params, adapter_cfg, images)
    inputs = {"tokens": jnp.zeros((images.shape[0], 0), jnp.int32),
              "patch_embeds": patch_embeds}
    return T.prefill(params["backbone"], backbone_cfg, inputs, max_len)


def prefill_prompt(params: Params, backbone_cfg: ArchConfig,
                   adapter_cfg: EOAdapterConfig, task: str,
                   images: jax.Array, prompts: jax.Array,
                   extra_len: int) -> Tuple[jax.Array, Tuple, jax.Array]:
    """Prefill [regions | prompt]; cache sized for the answer."""
    return prefill_tokens(params, backbone_cfg, adapter_cfg, images,
                          adapter_cfg.prompt_token(task, prompts),
                          adapter_cfg.n_regions + 1 + extra_len)


def decode_chunk(params: Params, backbone_cfg: ArchConfig, cache: Tuple,
                 first_logits: jax.Array, index: jax.Array, n_tokens: int,
                 answer_vocab: int
                 ) -> Tuple[jax.Array, jax.Array, Tuple, jax.Array, jax.Array]:
    """Greedy-decode ``n_tokens`` answer tokens restricted to the answer
    vocabulary. Returns (tokens (B,n), probs (B,n,V_ans), cache, last_logits,
    next_index)."""
    b = first_logits.shape[0]
    toks, probs = [], []
    logits = first_logits
    for _ in range(n_tokens):
        a_logits = logits[:, :answer_vocab]
        p = jax.nn.softmax(a_logits, axis=-1)
        nxt = jnp.argmax(a_logits, axis=-1).astype(jnp.int32)
        toks.append(nxt)
        probs.append(p)
        logits, cache = T.decode_step(
            params["backbone"], backbone_cfg, cache,
            {"tokens": nxt[:, None]}, index)
        index = index + 1
    return (jnp.stack(toks, 1), jnp.stack(probs, 1), cache, logits, index)


def generate(params: Params, backbone_cfg: ArchConfig,
             adapter_cfg: EOAdapterConfig, task: str, images: jax.Array,
             prompts: jax.Array, answer_vocab: int
             ) -> Tuple[jax.Array, jax.Array]:
    """Full greedy answer: returns (tokens (B, L_ans), probs (B, L_ans, V))."""
    l_ans = adapter_cfg.answer_len(task)
    logits, cache, idx = prefill_prompt(params, backbone_cfg, adapter_cfg,
                                        task, images, prompts, l_ans)
    toks, probs, *_ = decode_chunk(params, backbone_cfg, cache, logits, idx,
                                   l_ans, answer_vocab)
    return toks, probs


def prediction_from_tokens(task: str, tokens: jax.Array) -> jax.Array:
    """tokens (B, L_ans) → task prediction (label id or region mask)."""
    if task in ("vqa", "cls"):
        return tokens[:, 0]
    return tokens  # det: (B, R) 0/1 mask
