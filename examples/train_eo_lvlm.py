"""Training driver: pre-train both LVLM tiers on synthetic EO tasks with the
full training runtime (AdamW, grad accumulation, gradient compression,
async checkpointing + resume), then fit the confidence network.

    PYTHONPATH=src python examples/train_eo_lvlm.py --scale small --steps 200
    PYTHONPATH=src python examples/train_eo_lvlm.py --scale example   # ~110M GS tier

``--scale example`` trains the ~110M-parameter GS proxy — a few hundred steps
is hours on this CPU container but the intended few-hundred-step run on real
hardware; ``small`` (default) completes in minutes and exercises every code
path including checkpoint-restart.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import numpy as np

from repro.configs.spaceverse_pair import proxy_pair
from repro.core import eo_adapter as EO
from repro.core import pipeline as P
from repro.core.cascade import TierModel
from repro.data import synthetic
from repro.train import checkpoint as CK
from repro.train import compression as GC
from repro.train import optimizer as O


def train_tier(name, cfg, adapter_cfg, train_data, steps, lr, ckpt_dir,
               batch_size=16, compress=False):
    """Multi-task training with checkpoint/resume + the full opt stack."""
    key = jax.random.PRNGKey(hash(name) % 2 ** 31)
    params = EO.init_adapter(key, cfg, adapter_cfg)
    opt_cfg = O.OptConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                          total_steps=steps, weight_decay=0.0)
    opt_state = O.init_opt_state(params)
    err = GC.init_error_state(params) if compress else None
    comp_cfg = GC.CompressionConfig(scheme="int8") if compress else None

    start = 0
    if CK.latest_step(ckpt_dir) is not None:
        state, start = CK.restore(ckpt_dir, {"p": params, "o": opt_state})
        params, opt_state = state["p"], state["o"]
        print(f"[{name}] resumed from step {start}")

    from repro.models import transformer as T

    def loss_fn(params, batch):
        mb = {k: v for k, v in batch.items() if k != "raw_regions"}
        mb["patch_embeds"] = batch["raw_regions"] @ params["patch_proj"]
        return T.loss_fn(params["backbone"], cfg, mb, remat=False)

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, grads

    ck = CK.AsyncCheckpointer(ckpt_dir, keep=2)
    tasks = list(train_data)
    dtype = params["patch_proj"].dtype
    t0 = time.time()
    for s in range(start, steps):
        task = tasks[s % len(tasks)]
        key, sub = jax.random.split(key)
        n = train_data[task]["images"].shape[0]
        idx = np.asarray(jax.random.permutation(sub, n)[:batch_size])
        batch = P._task_batch(adapter_cfg, task, train_data[task], idx, dtype)
        loss, grads = step_fn(params, opt_state, batch)
        if compress:
            grads, err = GC.compress_grads(grads, err, comp_cfg)
        params, opt_state, stats = O.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        if (s + 1) % 50 == 0 or s + 1 == steps:
            ck.save_async(s + 1, {"p": params, "o": opt_state})
            print(f"[{name}] step {s+1}/{steps} loss={float(loss):.4f} "
                  f"lr={float(stats['lr']):.2e} "
                  f"({(time.time()-t0)/(s-start+1):.2f}s/step)", flush=True)
    ck.wait()
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "example"], default="small")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--n-train", type=int, default=384)
    ap.add_argument("--ckpt", default="results/eo_lvlm_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    sat_cfg, gs_cfg = proxy_pair(args.scale)
    print(f"tiers: W^s={sat_cfg.param_count()/1e6:.1f}M params, "
          f"W^g={gs_cfg.param_count()/1e6:.1f}M params")
    ac = EO.EOAdapterConfig()
    eo_cfg = synthetic.EOTaskConfig(image_size=ac.image_size, grid=ac.grid,
                                    num_classes=ac.num_classes)
    train = {t: synthetic.make_dataset(t, args.n_train, seed=i, cfg=eo_cfg)
             for i, t in enumerate(P.TASKS)}
    test = {t: synthetic.make_dataset(t, 96, seed=100 + i, cfg=eo_cfg)
            for i, t in enumerate(P.TASKS)}

    sat_p = train_tier("sat", sat_cfg, ac, train, args.steps, 3e-3,
                       args.ckpt + "/sat", compress=args.compress_grads)
    gs_p = train_tier("gs", gs_cfg, ac, train, int(args.steps * 1.5), 2e-3,
                      args.ckpt + "/gs", compress=args.compress_grads)
    sat, gs = TierModel(sat_p, sat_cfg), TierModel(gs_p, gs_cfg)

    print("== fitting progressive confidence network (5% split) ==")
    conf, losses = P.train_confidence_net(sat, gs, ac, train, 9,
                                          steps=300)
    print(f"conf loss {losses[0]:.4f} → {losses[-1]:.4f}")

    from repro.core.cascade import CascadeConfig, SpaceVerse
    sv = SpaceVerse(sat, gs, ac, conf, CascadeConfig(answer_vocab=9))
    for task in P.TASKS:
        r = sv.evaluate(task, test)
        print(f"{task}: perf={r['performance']:.3f} "
              f"lat={r['latency_s']:.3f}s offload={r['offload_rate']:.2f}")


if __name__ == "__main__":
    main()
