"""Eq. 2 / Eq. 3 walkthrough: score regions, filter, count bytes.

    PYTHONPATH=src python examples/multiscale_demo.py

Prints an ASCII region-score map for a detection sample, the per-region
decision (discard / downsample level / preserve), and the transmission
ledger — the paper's Fig. 7/12c in text form.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import eo_adapter as EO
from repro.core import pipeline as P
from repro.core import preprocess as PP
from repro.core import region_attention as RA
from repro.data import synthetic


def main():
    bundle = P.build_system(scale="small", n_train=160, n_test=32,
                            proxy_steps=120, conf_steps=80, seed=0,
                            tasks=("det",))
    ac = bundle.adapter_cfg
    data = bundle.datasets["det"]
    images = jnp.asarray(data["images"][:4])
    prompts = jnp.asarray(data["prompts"][:4])

    rf = EO.encode_regions(bundle.sat.params, ac, images)
    tf = EO.encode_text(bundle.sat.params, bundle.sat.cfg,
                        ac.prompt_token("det", prompts))
    raw, norm = RA.score_regions(rf[:, :, None, :], tf)
    regions = synthetic.regions_of(images, ac.grid)
    filt, txb, meta = PP.multiscale_filter(regions, norm)

    for s in range(2):
        print(f"\n== sample {s} (target class {int(prompts[s])}) ==")
        score = np.asarray(norm[s]).reshape(ac.grid, ac.grid)
        rel = np.asarray(data["region_rel"][s]).reshape(ac.grid, ac.grid)
        lvl = np.asarray(meta["levels"][s]).reshape(ac.grid, ac.grid)
        drop = np.asarray(meta["discarded"][s]).reshape(ac.grid, ac.grid)
        print("Eq.2 scores (× = ground-truth relevant region):")
        for r in range(ac.grid):
            print("  " + " ".join(
                f"{score[r, c]:.2f}{'×' if rel[r, c] else ' '}"
                for c in range(ac.grid)))
        print("Eq.3 decisions (D=discard, digit=downsample level, K=keep):")
        for r in range(ac.grid):
            row = []
            for c in range(ac.grid):
                if drop[r, c]:
                    row.append("D")
                elif lvl[r, c] == 1:
                    row.append("K")
                else:
                    row.append(str(int(lvl[r, c])))
            print("  " + " ".join(row))
        print(f"bytes: {float(txb[s]):.0f} / "
              f"{float(meta['full_bytes'][s]):.0f} "
              f"(compression {float(meta['compression_ratio'][s]):.1f}:1)")


if __name__ == "__main__":
    main()
