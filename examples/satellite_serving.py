"""End-to-end serving driver: batched Earth-observation requests through the
two-tier SpaceVerse server with orbital contact windows.

    PYTHONPATH=src python examples/satellite_serving.py [--requests 48]

This is the paper's deployment story: a request stream arrives at the
satellite; the progressive confidence network triages each request; offloads
pass Eq. 2/Eq. 3 preprocessing and a Starlink-calibrated link whose contact
windows are simulated by the orbit model; the GS tier answers the rest.  The
demo also drops the link mid-stream to show graceful degradation to
satellite-only service.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import numpy as np

from repro.core import pipeline as P
from repro.network.orbit import ContactPlan
from repro.serving import CascadeServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--contact-fraction", type=float, default=1.0,
                    help="1.0 = always in contact; 0.0433 = paper's average")
    args = ap.parse_args()

    print("== training tiers + confidence network ==")
    bundle = P.build_system(scale="small", n_train=192, n_test=64,
                            proxy_steps=150, conf_steps=150, seed=0)
    server = CascadeServer(
        bundle.sat, bundle.gs, bundle.adapter_cfg, bundle.conf_params,
        bundle.cascade_cfg, bundle.latency,
        plan=ContactPlan(contact_fraction_override=args.contact_fraction))

    # request stream mixing the three tasks
    reqs = []
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        task = ("vqa", "cls", "det")[i % 3]
        data = bundle.datasets[task]
        j = int(rng.integers(0, data["images"].shape[0]))
        reqs.append(Request(task=task, image=data["images"][j],
                            prompt=int(data["prompts"][j]), t_arrival=i * 0.5))

    print(f"== serving {len(reqs)} requests ==")
    tiers = {"satellite": 0, "ground": 0}
    lat, tx = [], []
    for q, req in enumerate(reqs):
        if q == 2 * len(reqs) // 3:
            print("-- link DOWN: degrading to satellite-only --")
            server.link_up = False
        resp = server.handle(req, now=req.t_arrival)
        tiers[resp.tier] += 1
        lat.append(resp.latency_s)
        tx.append(resp.tx_bytes)
        if q < 8 or q == 2 * len(reqs) // 3:
            print(f"req {resp.request_id:3d} [{req.task}] → {resp.tier:9s} "
                  f"exit={resp.exit_stage} lat={resp.latency_s:6.3f}s "
                  f"tx={resp.tx_bytes/1e6:6.2f}MB")

    med, n_strag = server.scheduler.straggler_report()
    print(f"\nserved: {tiers}; mean latency {np.mean(lat):.3f}s; "
          f"downlinked {np.sum(tx)/1e6:.1f}MB; "
          f"median transfer {med:.3f}s; stragglers {n_strag}; "
          f"re-replicated {server.scheduler.n_replicated}")
    # the server and the batch evaluator share one executor (DESIGN.md
    # §serving): the same bundle evaluated in counterfactual mode
    res = bundle.spaceverse().evaluate("cls", bundle.datasets["cls"],
                                       batch_size=16)
    print(f"batch evaluator (same executor): performance "
          f"{res['performance']:.3f}, offload rate {res['offload_rate']:.2f}")


if __name__ == "__main__":
    main()
