"""End-to-end serving driver: batched Earth-observation requests through the
two-tier SpaceVerse server with orbital contact windows.

    PYTHONPATH=src python examples/satellite_serving.py [--requests 48]

This is the paper's deployment story: a request stream arrives at the
satellite; the progressive confidence network triages each request; offloads
pass Eq. 2/Eq. 3 preprocessing and a Starlink-calibrated link whose contact
windows are simulated by the orbit model; the GS tier answers the rest.  The
demo also drops the link mid-stream to show graceful degradation to
satellite-only service, then fans several prompts out over ONE captured
scene to show the paged KV cache sharing the image-region prefix across
queries (the region tokens prefill once; every further query only runs its
prompt suffix).  A final section turns on chunked prefill
(``EngineCoreConfig(prefill_chunk=C)``): admission stops running the scene
prefill as one synchronous call and instead streams it into the paged
cache a few region tokens per fused token-budget step, printing the
per-step decode/prompt/chunk token mix and the measured TTFT with
chunking on vs off.  The closing section saturates a tiny engine with
bulk mapping work and injects urgent queries mid-burst, with overload
control (``EngineCoreConfig(overload=OverloadConfig(...))``) on vs off:
on, the bounded priority queue defers/rejects bulk explicitly and the
urgent arrivals preempt their way into slots; off, they wait FIFO behind
the whole backlog.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import numpy as np

from repro.core import pipeline as P
from repro.network.orbit import ContactPlan
from repro.serving import (CascadeServer, EngineConfig, EngineCore,
                           EngineCoreConfig, InferenceEngine, Request)
from repro.serving.engine_core import shared_core


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--contact-fraction", type=float, default=1.0,
                    help="1.0 = always in contact; 0.0433 = paper's average")
    ap.add_argument("--fanout", type=int, default=8,
                    help="queries fanned out over one scene in the paged-"
                         "KV prefix-sharing demo")
    args = ap.parse_args()

    print("== training tiers + confidence network ==")
    bundle = P.build_system(scale="small", n_train=192, n_test=64,
                            proxy_steps=150, conf_steps=150, seed=0)
    server = CascadeServer(
        bundle.sat, bundle.gs, bundle.adapter_cfg, bundle.conf_params,
        bundle.cascade_cfg, bundle.latency,
        plan=ContactPlan(contact_fraction_override=args.contact_fraction))

    # request stream mixing the three tasks
    reqs = []
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        task = ("vqa", "cls", "det")[i % 3]
        data = bundle.datasets[task]
        j = int(rng.integers(0, data["images"].shape[0]))
        reqs.append(Request(task=task, image=data["images"][j],
                            prompt=int(data["prompts"][j]), t_arrival=i * 0.5))

    print(f"== serving {len(reqs)} requests ==")
    tiers = {"satellite": 0, "ground": 0}
    lat, tx = [], []
    for q, req in enumerate(reqs):
        if q == 2 * len(reqs) // 3:
            print("-- link DOWN: degrading to satellite-only --")
            server.link_up = False
        resp = server.handle(req, now=req.t_arrival)
        tiers[resp.tier] += 1
        lat.append(resp.latency_s)
        tx.append(resp.tx_bytes)
        if q < 8 or q == 2 * len(reqs) // 3:
            print(f"req {resp.request_id:3d} [{req.task}] → {resp.tier:9s} "
                  f"exit={resp.exit_stage} lat={resp.latency_s:6.3f}s "
                  f"tx={resp.tx_bytes/1e6:6.2f}MB")

    med, n_strag = server.scheduler.straggler_report()
    print(f"\nserved: {tiers}; mean latency {np.mean(lat):.3f}s; "
          f"downlinked {np.sum(tx)/1e6:.1f}MB; "
          f"median transfer {med:.3f}s; stragglers {n_strag}; "
          f"re-replicated {server.scheduler.n_replicated}")
    # the server and the batch evaluator share one executor (DESIGN.md
    # §serving): the same bundle evaluated in counterfactual mode
    res = bundle.spaceverse().evaluate("cls", bundle.datasets["cls"],
                                       batch_size=16)
    print(f"batch evaluator (same executor): performance "
          f"{res['performance']:.3f}, offload rate {res['offload_rate']:.2f}")

    # -- scene fan-out: many prompts over ONE captured scene ---------------
    # the dominant on-satellite traffic shape: cls + det + a batch of VQA
    # questions about the same tile.  The paged engine prefills the 16
    # region tokens once and maps their KV pages read-only into every
    # query's block table — watch the prefix hit rate.
    print(f"\n== scene fan-out over one image ({args.fanout} queries, "
          "paged KV prefix sharing) ==")
    eng = InferenceEngine(bundle.sat.params, bundle.sat.cfg,
                          bundle.adapter_cfg,
                          EngineConfig(slots=4, answer_vocab=9))
    eng.warmup()
    scene_img = bundle.datasets["cls"]["images"][0]
    fan = [Request(task="det", image=scene_img, prompt=0, scene_id="tile-0"),
           Request(task="cls", image=scene_img, prompt=0, scene_id="tile-0")]
    fan += [Request(task="vqa", image=scene_img, prompt=q % 2,
                    scene_id="tile-0")
            for q in range(max(args.fanout - 2, 0))]
    resps = eng.serve(fan)
    st = eng.core.stats
    kv = eng.core.kv_stats()
    n_regions = bundle.adapter_cfg.n_regions
    print(f"answered {len(resps)} queries over one scene: "
          f"prefix hit rate {kv['prefix_hit_rate']:.2f} "
          f"({st['prefix_hits']} hits / {st['prefix_misses']} miss)")
    print(f"prefilled {st['prefill_tokens']} tokens total "
          f"(dense would prefill {len(fan) * (n_regions + 1)}); "
          f"amortised KV {kv['kv_bytes_per_slot']} B/slot "
          f"across {kv['pages_in_use']} live pages")

    # -- cascade-speculative decoding on the ground tier -------------------
    # the cascade pair IS a speculative pair: the compact satellite model
    # drafts γ tokens per slot and W^g verifies them in one multi-token
    # scoring step (token-for-token identical to greedy decode).  Offloaded
    # requests arrive with the satellite's answer already computed — those
    # tokens piggyback on the downlink payload as free drafts; the rest
    # draft with the local compact model.
    gamma = 4
    print(f"\n== cascade-speculative decoding on the ground tier "
          f"(γ={gamma}) ==")
    spec_core = EngineCore(
        bundle.gs, bundle.adapter_cfg,
        EngineCoreConfig(slots=4, answer_vocab=9, spec_gamma=gamma),
        draft=bundle.sat)
    spec_core.warmup()
    sat_core = shared_core(bundle.sat, bundle.adapter_cfg)
    det = bundle.datasets["det"]
    spec_reqs = []
    for i in range(8):
        img = det["images"][i]
        req = Request(task="det", image=img, prompt=int(det["prompts"][i]))
        if i % 2 == 0:      # offloaded half: satellite answer rides along
            toks, _ = sat_core.generate(
                "det", np.asarray(img)[None],
                np.asarray([int(det["prompts"][i])], np.int32), 9)
            req.draft_tokens = np.asarray(toks)[0].astype(np.int32)
        spec_reqs.append(req)
    queue = list(reversed(spec_reqs))
    while queue or spec_core.active_count():
        n = min(len(queue), len(spec_core.free_slots()))
        if n:
            spec_core.admit_many([queue.pop() for _ in range(n)])
        spec_core.step()
    sp = spec_core.spec_stats()
    local = sp["drafted"] - sp["piggybacked"]
    print(f"answered {spec_core.stats['finished']} det queries "
          f"speculatively: accept rate {sp['accept_rate']:.2f}, "
          f"{sp['tokens_per_slot_step']:.2f} tokens/slot-step "
          f"(greedy commits 1.0)")
    print(f"draft sources: {sp['piggybacked']} piggybacked from the "
          f"satellite's downlinked answer, {local} drafted locally by the "
          f"compact model; {sp['verify_only_steps']}/{sp['steps']} steps "
          f"skipped the drafter entirely")

    _chunked_demo(bundle, args.fanout)
    _overload_demo(bundle)


def _chunked_demo(bundle, fanout: int) -> None:
    """Continuous-arrival chunked prefill: admission streams each new
    scene's region tokens into the paged cache a few per fused step, next
    to everyone else's decode tokens — the engine never stops decoding to
    admit.  Prints the per-step token mix and the measured TTFT with
    chunking on vs off (same requests, token-for-token equal answers)."""
    import time

    import numpy as np

    from repro.serving import EngineCore, EngineCoreConfig, Request
    from repro.core.cascade import TierModel

    print("\n== chunked prefill: admission fused into the decode step ==")
    scenes = bundle.datasets["cls"]["images"]
    n_regions = bundle.adapter_cfg.n_regions

    def stream(tag):
        reqs = []
        for s in range(4):
            img = scenes[s % len(scenes)]
            reqs.append(Request(task="det", image=img, prompt=0,
                                scene_id=f"{tag}-{s}"))
            reqs += [Request(task="vqa", image=img, prompt=q % 2,
                             scene_id=f"{tag}-{s}")
                     for q in range(max(fanout // 2 - 1, 1))]
        return reqs

    results = {}
    for chunk in (0, 8):
        core = EngineCore(TierModel(bundle.sat.params, bundle.sat.cfg),
                          bundle.adapter_cfg,
                          EngineCoreConfig(slots=4, answer_vocab=9,
                                           prefill_chunk=chunk))
        core.warmup()
        queue = list(reversed(stream(f"c{chunk}")))
        outs = {}
        while queue or core.active_count():
            n = min(len(queue), len(core.free_slots()))
            if n:
                core.admit_many([queue.pop() for _ in range(n)])
            for r, t in core.step():
                outs[r.request_id] = t.tolist()
        log = core.stats["request_log"]
        ttft = sorted(r["t_first"] - r["t_admit"] for r in log)
        results[chunk] = {"outs": [outs[k] for k in sorted(outs)],
                          "ttft_ms": ttft[len(ttft) // 2] * 1e3,
                          "core": core}
    chunked = results[8]["core"]
    mix = chunked.stats["sched"]["step_log"]
    print(f"per-step token mix of the first fused steps "
          f"(decode/prompt/chunk), budget "
          f"{chunked.scheduler_stats()['budget']}:")
    for i, (d, p, c) in enumerate(mix[:8]):
        bar = "D" * d + "P" * p + "c" * c
        print(f"  step {i:2d}: {d:2d} decode + {p} prompt + {c:2d} chunk  "
              f"|{bar}|")
    st = chunked.scheduler_stats()
    print(f"{st['fused_steps']} fused steps, budget utilisation "
          f"{st['budget_utilization']:.2f}, prefill by kind "
          f"{st['prefill_by_kind']} "
          f"(the {n_regions}-token scene prefix streams as "
          f"'chunk' tokens instead of one synchronous admission call)")
    same = results[0]["outs"] == results[8]["outs"]
    print(f"TTFT p50: {results[8]['ttft_ms']:.2f}ms chunked vs "
          f"{results[0]['ttft_ms']:.2f}ms stall admission; outputs "
          f"token-for-token equal: {same}  (at this demo's toy 16-token "
          f"scenes the stall is tiny — benchmarks/serving_bench.py "
          f"measures production-shaped 256-token scenes, where the "
          f"urgent-query TTFT halves)")


def _overload_demo(bundle) -> None:
    """Graceful degradation under saturation, overload control on vs off:
    the same bulk det burst floods a 2-slot engine, then two urgent vqa
    queries arrive mid-burst.  Controlled, the queue stays bounded (excess
    bulk is rejected with an explicit reason) and the urgent pair preempts
    straight into slots; uncontrolled, everything queues FIFO and the
    urgent queries wait behind the entire backlog."""
    import time

    from collections import Counter

    from repro.core.cascade import TierModel
    from repro.serving import (EngineCore, EngineCoreConfig, OverloadConfig,
                               PRIORITY_BULK, PRIORITY_URGENT, Request)

    print("\n== overload control: urgent queries under bulk saturation ==")
    scenes = bundle.datasets["cls"]["images"]
    tier = TierModel(bundle.sat.params, bundle.sat.cfg)

    def burst(tag):
        bulk = [Request(task="det", image=scenes[i % len(scenes)], prompt=0,
                        scene_id=f"{tag}-b{i}", priority=PRIORITY_BULK)
                for i in range(8)]
        urgent = [Request(task="vqa", image=scenes[(8 + i) % len(scenes)],
                          prompt=i % 2, scene_id=f"{tag}-u{i}",
                          priority=PRIORITY_URGENT) for i in range(2)]
        return bulk, urgent

    # -- control ON: bounded queue, priority admission, preemption ---------
    core = EngineCore(tier, bundle.adapter_cfg,
                      EngineCoreConfig(slots=2, answer_vocab=9,
                                       overload=OverloadConfig(queue_cap=4)))
    core.warmup()
    bulk, urgent = burst("on")
    out = core.submit_many(bulk)
    print(f"bulk burst of {len(bulk)} on 2 slots (queue cap 4): "
          f"{dict(Counter(out[r.request_id] for r in bulk))}")
    for _ in range(3):
        core.step()
    out_u = core.submit_many(urgent)
    ol = core.scheduler_stats()["overload"]
    print(f"2 urgent vqa arrive mid-burst: "
          f"{dict(Counter(out_u[r.request_id] for r in urgent))} "
          f"(preempted {ol['preemptions']} bulk slots to take them)")
    n_done = 0
    while core.active_count() or core.queue_depth():
        n_done += len(core.step())
    ol = core.scheduler_stats()["overload"]
    print(f"drained: {n_done} completed, queue peak {ol['queue_peak']}, "
          f"deferred {ol['admissions_deferred']}, "
          f"rejections {ol['rejections']}, re-admission wait p50 "
          f"{ol['readmit_wait_ms']['p50']:.1f}ms")
    names = {PRIORITY_BULK: "bulk", PRIORITY_URGENT: "URGENT"}
    ttft_on = {}
    for p, v in ol["ttft_by_priority"].items():
        ttft_on[p] = v["p99_ms"]
        print(f"  {names.get(p, p):6s} TTFT-from-submit p50 "
              f"{v['p50_ms']:6.1f}ms  p99 {v['p99_ms']:6.1f}ms  "
              f"({v['n']} completed)")

    # -- control OFF: the pre-overload deployment (unbounded host FIFO) ----
    base = EngineCore(tier, bundle.adapter_cfg,
                      EngineCoreConfig(slots=2, answer_vocab=9))
    base.warmup()
    bulk, urgent = burst("off")
    base.stats["request_log"].clear()
    arrive = {}
    fifo = list(bulk)
    for r in bulk:
        arrive[r.request_id] = time.perf_counter()
    steps = 0
    while fifo or base.active_count():
        n = min(len(fifo), len(base.free_slots()))
        if n:
            base.admit_many(fifo[:n])
            del fifo[:n]
        base.step()
        steps += 1
        if steps == 3:                    # urgent joins the back of the line
            for r in urgent:
                arrive[r.request_id] = time.perf_counter()
            fifo += urgent
    prio_of = {r.request_id: r.priority for r in bulk + urgent}
    ttft_off = {}
    for p in (PRIORITY_BULK, PRIORITY_URGENT):
        ts = sorted((r["t_first"] - arrive[r["request_id"]]) * 1e3
                    for r in base.stats["request_log"]
                    if prio_of[r["request_id"]] == p)
        ttft_off[p] = ts[-1]
        print(f"  {names[p]:6s} TTFT without control: p50 "
              f"{ts[len(ts) // 2]:6.1f}ms  worst {ts[-1]:6.1f}ms  "
              f"(all {len(ts)} served FIFO)")
    if ttft_on.get(PRIORITY_URGENT):
        print(f"urgent tail with control on: {ttft_on[PRIORITY_URGENT]:.1f}ms"
              f" vs {ttft_off[PRIORITY_URGENT]:.1f}ms off "
              f"({ttft_off[PRIORITY_URGENT] / ttft_on[PRIORITY_URGENT]:.1f}×"
              " better) — bulk pays with deferrals/rejections instead of "
              "the urgent class paying with latency")


if __name__ == "__main__":
    main()
