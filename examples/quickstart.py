"""Quickstart: train a tiny two-tier system and run the SpaceVerse cascade.

    PYTHONPATH=src python examples/quickstart.py

Trains laptop-scale satellite/GS proxy LVLMs on synthetic Earth-observation
tasks (~1 min on CPU), fits the progressive confidence network on a 5 %
split, then answers a batch of classification queries through Algorithm 1 —
printing, per sample, where it exited, what was transmitted, and the
latency ledger at the paper's deployment scale (Qwen2-VL-2B/7B, Starlink
link).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import pipeline as P


def main():
    print("== training tiers + confidence network (tiny scale) ==")
    bundle = P.build_system(scale="small", n_train=192, n_test=64,
                            proxy_steps=150, conf_steps=150, seed=0,
                            tasks=("vqa", "cls"))
    sv = bundle.spaceverse()

    task = "cls"
    data = bundle.datasets[task]
    out = sv.run_batch(task, data["images"][:16], data["prompts"][:16])

    print(f"\n== cascade decisions ({task}) ==")
    off = np.asarray(out["offload"])
    stage = np.asarray(out["exit_stage"])
    for i in range(16):
        route = (f"offload@stage{stage[i]+1}" if off[i] else "onboard")
        print(f"sample {i:2d}: conf={np.asarray(out['conf_scores'])[i]} "
              f"→ {route:16s} tx={out['tx_bytes'][i]/1e6:6.2f}MB "
              f"latency={out['latency_s'][i]:.3f}s")

    res = sv.evaluate(task, data)
    print(f"\n{task}: performance={res['performance']:.3f} "
          f"mean latency={res['latency_s']:.3f}s "
          f"offload rate={res['offload_rate']:.2f}")


if __name__ == "__main__":
    main()
